"""End-to-end system behaviour: the paper's workflow through the framework.

Covers the full deployment loop: train exactly -> select a multiplier from
the registry -> evaluate the accuracy/PPA trade-off -> serve with the
chosen numerics.  Hypothesis property tests on system invariants live
in test_hypothesis_properties.py (skipped when hypothesis is absent).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ppa
from repro.core.afpm import AFPMConfig, afpm_mult_f32
from repro.core.metrics import mred
from repro.core.registry import get_multiplier


def test_accuracy_ppa_pareto_frontier():
    """System invariant: within the AC family, accuracy and hardware cost
    are monotone in n — the knob is a real Pareto frontier."""
    rng = np.random.default_rng(0)
    x = rng.uniform(-4, 4, 50_000).astype(np.float32)
    y = rng.uniform(-4, 4, 50_000).astype(np.float32)
    exact = x.astype(np.float64) * y.astype(np.float64)
    prev_err, prev_area = None, None
    for n in (3, 4, 5, 6, 7):
        err = mred(np.asarray(afpm_mult_f32(x, y, AFPMConfig(n=n))), exact)
        area = ppa.estimate("ac", n=n).logic_area_um2
        if prev_err is not None:
            assert err < prev_err and area > prev_area, (n, err, area)
        prev_err, prev_area = err, area


@pytest.mark.slow
def test_end_to_end_deploy_loop():
    """Train a small LM exactly, then serve under segmented numerics; the
    accuracy knob must degrade gracefully (3 passes ~ exact, 1 pass worse)."""
    from repro.configs import get_arch
    from repro.launch.serve import serve
    from repro.launch.train import train

    params, _, losses = train("qwen3-4b", steps=25, seq_len=64, batch=4,
                              log_every=100)
    assert losses[-1] < losses[0]
    cfg = get_arch("qwen3-4b").reduced()
    ref = serve(batch=2, prompt_len=16, gen_len=6, numerics="exact",
                params=params, cfg=cfg, seed=11)
    seg3 = serve(batch=2, prompt_len=16, gen_len=6, numerics="segmented3",
                 params=params, cfg=cfg, seed=11)
    seg1 = serve(batch=2, prompt_len=16, gen_len=6, numerics="segmented1",
                 params=params, cfg=cfg, seed=11)
    agree3 = (ref == seg3).mean()
    agree1 = (ref == seg1).mean()
    assert agree3 >= agree1 - 1e-9, (agree3, agree1)
    assert agree3 >= 0.5


def test_checkpoint_then_elastic_reshard_roundtrip(tmp_path):
    """Fault-tolerance invariant: a checkpoint written under one layout
    restores exactly under another (elastic re-shard)."""
    from repro.checkpoint import io as ckpt_io
    from repro.distributed.fault import plan_elastic_mesh

    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    d = str(tmp_path / "ck")
    ckpt_io.save(d, 1, tree)
    # simulate losing chips: 512 -> 384 alive, model parallel 16 kept
    data, model = plan_elastic_mesh(384, 16)
    assert (data, model) == (16, 16)
    restored, _ = ckpt_io.restore(d, tree)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
