"""Integration: real training loops converge; checkpoint/restart is exact;
pipeline parallelism matches sequential execution; adafactor works.

The training-loop tests are marked ``slow`` (deselected by default, run
with ``pytest -m ''`` or in CI's full job) to keep the default run fast."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import train
from repro.optim import adafactor


@pytest.mark.slow
def test_lm_training_loss_decreases(tmp_path):
    # lr sized for the reduced 2-layer/d=64 config (the 3e-4 default is
    # tuned for the full-size archs and barely moves in 30 steps)
    _, _, losses = train("qwen3-4b", steps=30, seq_len=64, batch=4,
                         ckpt_dir=None, log_every=10, lr=3e-3)
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.2, (first, last)  # markov data is learnable


@pytest.mark.slow
def test_checkpoint_restart_exact(tmp_path):
    d = str(tmp_path / "ck")
    # run 20 steps with checkpointing every 10
    p1, o1, l1 = train("qwen3-4b", steps=20, seq_len=32, batch=4,
                       ckpt_dir=d, ckpt_every=10, log_every=50)
    # fresh process-equivalent: restore at 10 and continue to 20
    p2, o2, l2 = train("qwen3-4b", steps=20, seq_len=32, batch=4,
                       ckpt_dir=d.replace("ck", "ck2"), ckpt_every=10, log_every=50)
    # deterministic data + init => identical trajectories
    np.testing.assert_allclose(l1, l2, rtol=1e-5)
    # now simulate failure: restore from step 10 checkpoint in dir d and
    # continue; final params must match the uninterrupted run
    from repro.checkpoint import io as ckpt_io

    steps_avail = ckpt_io.all_steps(d)
    assert 10 in steps_avail and 20 in steps_avail
    p3, o3, l3 = train("qwen3-4b", steps=20, seq_len=32, batch=4,
                       ckpt_dir=d, ckpt_every=10, log_every=50)  # resumes at 20
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p3)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_adafactor_converges_and_is_small():
    k = jax.random.PRNGKey(0)
    W = jax.random.normal(k, (256, 256)) / 16
    params = {"w": jnp.zeros((256, 256))}
    # decaying lr: with a constant step size the rms-clipped updates orbit
    # the optimum at ~lr scale (Adafactor's documented behaviour)
    cfg = adafactor.AdafactorConfig(lr=0.05, schedule="cosine", warmup_steps=10,
                                    total_steps=300, grad_clip=10.0)
    state = adafactor.init(params, cfg)
    # factored: second-moment state must be ~0 bytes vs the params
    v_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(state.v))
    p_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
    assert v_bytes < p_bytes * 0.02, (v_bytes, p_bytes)

    loss = lambda p: jnp.mean((p["w"] - W) ** 2)
    l0 = float(loss(params))
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = adafactor.apply_updates(params, g, state, cfg)
    assert float(loss(params)) < max(1e-3, l0 * 0.02)


def test_pipeline_parallel_matches_sequential():
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 host devices (see tests/conftest settings)")
    from repro.distributed.pipeline import bubble_fraction, pipeline_apply
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh((2,), ("pipe",))
    S, M, mb, D = 2, 4, 3, 8
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (S, D, D)) / D ** 0.5}

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"])

    x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, D))
    got = pipeline_apply(mesh, stage_fn, params, x)
    # sequential reference
    want = x
    for s in range(S):
        want = stage_fn({"w": params["w"][s]}, want)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
    assert bubble_fraction(2, 3) == pytest.approx(1 / 4)


@pytest.mark.slow
def test_serve_numerics_knob_runs():
    from repro.launch.serve import serve

    toks_exact = serve("qwen3-4b", batch=2, prompt_len=16, gen_len=4,
                       numerics="exact", seed=3)
    toks_seg = serve("qwen3-4b", batch=2, prompt_len=16, gen_len=4,
                     numerics="segmented3", seed=3)
    assert toks_exact.shape == (2, 4)
    # 3-pass split-float is accurate enough to keep greedy tokens stable
    assert (toks_exact == toks_seg).mean() >= 0.75
