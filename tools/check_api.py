#!/usr/bin/env python3
"""Public-API surface check: ``repro.numerics`` and ``repro.session``.

Snapshots every ``__all__`` export of the two public modules — kind
(function / class / value) and ``inspect`` signature, plus public method
signatures for classes — into ``tests/golden/api_surface.json``.  CI (and
``tests/test_api_surface.py``) fails on any undeclared drift, so breaking
the surface requires an explicit regeneration in the same commit:

    PYTHONPATH=src python tools/check_api.py --write

Run with no arguments to verify (exit 1 + a diff summary on drift).
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN = os.path.join(REPO, "tests", "golden", "api_surface.json")
MODULES = ["repro.numerics", "repro.session"]


def _signature(obj) -> str:
    try:
        return str(inspect.signature(obj))
    except (ValueError, TypeError):
        return "(...)"


def _describe(obj) -> dict:
    if inspect.isclass(obj):
        methods = {}
        for name, fn in sorted(vars(obj).items()):
            if name.startswith("_") and name != "__init__":
                continue
            if isinstance(fn, property):
                methods[name] = "<property>"
            elif isinstance(fn, (classmethod, staticmethod)):
                methods[name] = _signature(fn.__func__)
            elif callable(fn):
                methods[name] = _signature(fn)
        return {"kind": "class", "signature": _signature(obj),
                "methods": methods}
    if callable(obj):
        return {"kind": "function", "signature": _signature(obj)}
    return {"kind": "value", "type": type(obj).__name__}


def snapshot() -> dict:
    out = {}
    for modname in MODULES:
        mod = importlib.import_module(modname)
        exports = {}
        for name in sorted(mod.__all__):
            exports[name] = _describe(getattr(mod, name))
        out[modname] = exports
    return out


def _flatten(d, prefix=""):
    for k, v in d.items():
        key = f"{prefix}.{k}" if prefix else k
        if isinstance(v, dict):
            yield from _flatten(v, key)
        else:
            yield key, v


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--write", action="store_true",
                    help="regenerate the golden snapshot")
    args = ap.parse_args()

    sys.path.insert(0, os.path.join(REPO, "src"))
    current = snapshot()
    if args.write:
        with open(GOLDEN, "w") as f:
            json.dump(current, f, indent=1, sort_keys=True)
            f.write("\n")
        n = sum(len(v) for v in current.values())
        print(f"[check_api] wrote {GOLDEN} ({n} exports)")
        return 0

    try:
        with open(GOLDEN) as f:
            golden = json.load(f)
    except OSError as e:
        print(f"[check_api] missing golden snapshot {GOLDEN}: {e}")
        return 1
    if current == golden:
        n = sum(len(v) for v in current.values())
        print(f"[check_api] OK: {n} exports match {os.path.relpath(GOLDEN, REPO)}")
        return 0

    cur = dict(_flatten(current))
    gold = dict(_flatten(golden))
    for key in sorted(gold.keys() - cur.keys()):
        print(f"[check_api] REMOVED: {key} (was {gold[key]!r})")
    for key in sorted(cur.keys() - gold.keys()):
        print(f"[check_api] ADDED:   {key} = {cur[key]!r}")
    for key in sorted(cur.keys() & gold.keys()):
        if cur[key] != gold[key]:
            print(f"[check_api] CHANGED: {key}: {gold[key]!r} -> {cur[key]!r}")
    print("[check_api] public API drift detected — if intentional, "
          "regenerate with: PYTHONPATH=src python tools/check_api.py --write")
    return 1


if __name__ == "__main__":
    sys.exit(main())
