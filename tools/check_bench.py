#!/usr/bin/env python
"""Perf-trajectory gate: diff a fresh ``BENCH_*.json`` against the
committed baseline with per-metric tolerance bands.

Policy (documented in ``docs/benchmarks.md``):

- **gated metrics** — units listed in ``benchmarks.harness.GATED_UNITS``
  (timing *ratios* like ``kern_seg_matmul_p3_vs_exact``, deterministic
  PPA-model outputs, PSNR accuracy) must stay inside their relative
  tolerance band; a violation fails the run.  Ratios are the stable,
  hardware-portable signal: both sides of the division are measured in
  the same process on the same machine.
- **informational metrics** — absolute wall-clock (``us`` etc.) varies
  with the host; deltas are printed but never fail shared-CPU CI.
- a gated metric present in the baseline but missing from the fresh run
  is a violation (a silently dropped benchmark is a regression too);
  extra fresh metrics are reported and ignored.

The same gate also validates and diffs the kernel-tuning artifacts
(``kernels/TUNE_<device>.json``, schema ``repro-tune/1``) via
``--tune-fresh`` / ``--tune-baseline``:

- structural validity (schema tag, well-formed entries) is gated — a
  corrupt or truncated artifact is exit code 2;
- a baseline entry missing from the fresh sweep is a violation (a key
  silently dropped from the sweep is a coverage regression);
- block-*choice* changes and timing drift are informational — the
  winner is a measured property of the host, so CI only requires that
  the sweep still runs, still covers every key, and still emits a valid
  table.  Device-kind and fast-mode mismatches are printed as notes.

Exit codes: ``0`` pass, ``1`` tolerance-band violation, ``2`` structured
error (missing/unreadable file, schema mismatch).

Usage::

    python tools/check_bench.py --baseline benchmarks/BENCH_cpu_ci.json \
        BENCH_fresh.json [--tolerance-scale S]
    python tools/check_bench.py --tune-baseline kernels/TUNE_cpu_ci.json \
        --tune-fresh /tmp/TUNE_fresh.json

Run by the ``bench`` job in ``.github/workflows/ci.yml`` and by
``tests/test_bench_harness.py``.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))

from benchmarks.harness import GATED_UNITS, SCHEMA  # noqa: E402

#: Per-metric relative-tolerance overrides (beat the per-unit default).
TOLERANCES: dict[str, float] = {
    # p1 is the cheapest segmented variant; its ratio to the exact matmul
    # sits near 1 and wobbles the most on loaded CI machines
    "kern_seg_matmul_p1_vs_exact": 0.75,
    # host-python scheduling overhead vs jitted decode shifts with CI load,
    # so the engine/solo balance wobbles more than pure-kernel ratios
    "serving_vs_solo_generate": 0.75,
    # tuned-vs-static chunk ratio sits near 1 on CPU (both chunks are
    # reasonable) and wobbles with load; the gate is that the tuned path
    # never becomes drastically slower than the static guess
    "autotuned_vs_static": 0.75,
    # the paged-KV accounting ratios are deterministic scheduling outputs
    # (page counts under a fixed workload, no wall-clock), so they gate
    # much tighter than timing ratios; the stall metric's baseline is 0,
    # so any stall at all exceeds the band
    "serving_pages_per_request": 0.10,
    "serving_kv_reservation_vs_maxlen": 0.10,
    "serving_longprompt_decode_stall": 0.10,
}


class BenchError(Exception):
    """Structured failure (exit code 2): bad file, bad schema."""


def load_report(path: str | Path) -> dict:
    p = Path(path)
    if not p.exists():
        raise BenchError(f"{p}: no such benchmark artifact (generate with: "
                         f"python -m benchmarks.run --json {p})")
    try:
        data = json.loads(p.read_text())
    except (OSError, json.JSONDecodeError) as e:
        raise BenchError(f"{p}: unreadable benchmark artifact: {e}")
    schema = data.get("schema")
    if schema != SCHEMA:
        raise BenchError(f"{p}: schema {schema!r} does not match this "
                         f"checker's {SCHEMA!r}; regenerate the artifact "
                         f"(python -m benchmarks.run --json) or use a "
                         f"matching tool version")
    for field in ("meta", "metrics"):
        if not isinstance(data.get(field), dict):
            raise BenchError(f"{p}: malformed artifact: missing {field!r}")
    for name, m in data["metrics"].items():
        if not isinstance(m, dict) or "value" not in m or "unit" not in m:
            raise BenchError(f"{p}: malformed metric {name!r}: expected "
                             f"{{value, unit, derived, meta}}")
    return data


def tolerance_for(name: str, unit: str) -> float | None:
    """Relative tolerance band for a gated metric; None = informational."""
    if name in TOLERANCES:
        return TOLERANCES[name]
    return GATED_UNITS.get(unit)


def compare(baseline: dict, fresh: dict, *, tolerance_scale: float = 1.0):
    """Diff two artifacts.  Returns ``(violations, infos)`` line lists."""
    violations, infos = [], []
    base_m, fresh_m = baseline["metrics"], fresh["metrics"]
    if baseline["meta"].get("fast") != fresh["meta"].get("fast"):
        infos.append("note: fast-mode flag differs between baseline and "
                     "fresh run; absolute numbers are not comparable")
    for name, b in sorted(base_m.items()):
        tol = tolerance_for(name, b["unit"])
        f = fresh_m.get(name)
        if f is None:
            if tol is not None:
                violations.append(f"{name}: gated metric missing from "
                                  f"fresh run (baseline {b['value']:.4g} "
                                  f"{b['unit']})")
            else:
                infos.append(f"{name}: informational metric missing from "
                             f"fresh run")
            continue
        if f["unit"] != b["unit"]:
            violations.append(f"{name}: unit changed "
                              f"{b['unit']!r} -> {f['unit']!r}")
            continue
        bv, fv = b["value"], f["value"]
        rel = abs(fv - bv) / abs(bv) if bv else abs(fv)
        line = (f"{name}: {bv:.4g} -> {fv:.4g} {b['unit']} "
                f"({rel:+.1%} drift)")
        if tol is None:
            infos.append(line)
        elif rel > tol * tolerance_scale:
            violations.append(f"{line} exceeds ±{tol * tolerance_scale:.1%} band")
        else:
            infos.append(f"{line} within ±{tol * tolerance_scale:.1%} band")
    for name in sorted(set(fresh_m) - set(base_m)):
        infos.append(f"{name}: new metric (not in baseline) — "
                     f"{fresh_m[name]['value']:.4g} {fresh_m[name]['unit']}")
    return violations, infos


def load_tune(path: str | Path):
    """Load + structurally validate a tuning artifact (BenchError on
    anything ``repro.kernels.autotune.load`` rejects)."""
    from repro.kernels import autotune

    p = Path(path)
    if not p.exists():
        raise BenchError(f"{p}: no such tuning artifact (generate with: "
                         f"python -m benchmarks.autotune --out {p})")
    try:
        return autotune.load(str(p))
    except autotune.TuneError as e:
        raise BenchError(str(e))


def compare_tune(baseline, fresh):
    """Diff two TuningTables.  Returns ``(violations, infos)``.

    Coverage is gated (every baseline key must survive); the chosen
    blocks and their timings are informational — they are measured
    properties of the host the sweep ran on.
    """
    violations, infos = [], []
    if baseline.device != fresh.device:
        infos.append(f"note: device kind differs ({baseline.device!r} "
                     f"baseline vs {fresh.device!r} fresh); block choices "
                     f"are not comparable across devices")
    if baseline.meta.get("fast") != fresh.meta.get("fast"):
        infos.append("note: fast-mode flag differs between baseline and "
                     "fresh sweep; coverage and timings are not comparable")
    for key in sorted(baseline.entries):
        b = baseline.entries[key]
        f = fresh.entries.get(key)
        if f is None:
            violations.append(f"{key}: tuned entry missing from fresh sweep "
                              f"(baseline block {b['block']})")
            continue
        if f["block"] != b["block"]:
            infos.append(f"{key}: block {b['block']} -> {f['block']} "
                         f"({b['median_us']:.1f} -> {f['median_us']:.1f} us)")
        else:
            infos.append(f"{key}: block {b['block']} unchanged "
                         f"({b['median_us']:.1f} -> {f['median_us']:.1f} us)")
    for key in sorted(set(fresh.entries) - set(baseline.entries)):
        infos.append(f"{key}: new tuned entry (not in baseline) — "
                     f"block {fresh.entries[key]['block']}")
    return violations, infos


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="diff a fresh BENCH_*.json against the committed "
                    "perf-trajectory baseline (and/or a fresh "
                    "TUNE_*.json against the committed tuning artifact)")
    ap.add_argument("fresh", nargs="?", default=None,
                    help="freshly generated BENCH_*.json")
    ap.add_argument("--baseline", default=str(REPO / "benchmarks" / "BENCH_cpu_ci.json"),
                    help="committed trajectory artifact (default: "
                         "benchmarks/BENCH_cpu_ci.json)")
    ap.add_argument("--tolerance-scale", type=float, default=1.0,
                    help="scale every tolerance band (e.g. 2.0 to loosen "
                         "all bands 2x on a known-noisy host)")
    ap.add_argument("--tune-fresh", default=None, metavar="TUNE_JSON",
                    help="freshly swept kernel-tuning artifact to validate "
                         "(python -m benchmarks.autotune)")
    ap.add_argument("--tune-baseline",
                    default=str(REPO / "kernels" / "TUNE_cpu_ci.json"),
                    metavar="TUNE_JSON",
                    help="committed tuning artifact to diff against "
                         "(default: kernels/TUNE_cpu_ci.json)")
    args = ap.parse_args(argv)
    if args.fresh is None and args.tune_fresh is None:
        ap.error("nothing to check: pass a fresh BENCH_*.json and/or "
                 "--tune-fresh TUNE_*.json")

    violations = []
    sys.path.insert(0, str(REPO / "src"))
    if args.fresh is not None:
        try:
            baseline = load_report(args.baseline)
            fresh = load_report(args.fresh)
        except BenchError as e:
            print(f"ERROR {e}", file=sys.stderr)
            return 2
        violations, infos = compare(baseline, fresh,
                                    tolerance_scale=args.tolerance_scale)
        for line in infos:
            print(f"  {line}")
        for line in violations:
            print(f"FAIL {line}", file=sys.stderr)
        n_gated = sum(1 for n, m in baseline["metrics"].items()
                      if tolerance_for(n, m["unit"]) is not None)
        print(f"check_bench: {len(baseline['metrics'])} baseline metrics "
              f"({n_gated} gated), {len(violations)} violation(s)")

    if args.tune_fresh is not None:
        try:
            tune_base = load_tune(args.tune_baseline)
            tune_fresh = load_tune(args.tune_fresh)
        except BenchError as e:
            print(f"ERROR {e}", file=sys.stderr)
            return 2
        t_violations, t_infos = compare_tune(tune_base, tune_fresh)
        for line in t_infos:
            print(f"  {line}")
        for line in t_violations:
            print(f"FAIL {line}", file=sys.stderr)
        print(f"check_bench[tune]: {len(tune_base.entries)} baseline "
              f"entries, {len(t_violations)} violation(s)")
        violations = violations + t_violations

    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
