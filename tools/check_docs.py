#!/usr/bin/env python
"""Docs checker: link + anchor validation, fenced-example compilation,
and the generated-CLI-reference sync check.

Four failure modes this guards against as the APIs evolve:

1. broken intra-repo links — every relative ``[text](target)`` in the
   checked markdown files must point at an existing file (external
   ``http(s)://`` / ``mailto:`` links are skipped);
2. broken anchor fragments — a ``file.md#section`` (or in-page
   ``#section``) link must name a real heading of the target file, using
   GitHub's slug rules, so renaming a heading can no longer break links
   silently;
3. stale code examples — every fenced ```` ```python ```` block in
   ``docs/`` is extracted and byte-compiled (``python -m compileall``
   semantics via :func:`compile`), so syntax drift in examples fails CI;
4. stale generated CLI reference — ``docs/cli.md`` must match what
   ``tools/gen_cli_docs.py`` renders from the live
   ``python -m repro.session`` parser (skippable with
   ``--skip-cli-sync`` for environments without jax).

Usage: ``python tools/check_docs.py [--write-extracted DIR]
[--skip-cli-sync]``; exits non-zero on any problem.  Run by the ``docs``
job in ``.github/workflows/ci.yml`` and by ``tests/test_docs.py``.
"""
from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# files whose links are validated; python fences are compiled for docs/ only
LINK_CHECKED = ["README.md", "ROADMAP.md"]
DOCS_DIR = REPO / "docs"

_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)
_HEADING_RE = re.compile(r"^#{1,6}\s+(.*?)\s*$", re.MULTILINE)
_EXTERNAL = ("http://", "https://", "mailto:")


def _label(md: Path) -> str:
    try:
        return str(md.relative_to(REPO))
    except ValueError:
        return str(md)


def _md_files() -> list[Path]:
    files = [REPO / f for f in LINK_CHECKED if (REPO / f).exists()]
    files += sorted(DOCS_DIR.glob("**/*.md")) if DOCS_DIR.is_dir() else []
    return files


def _strip_fences(text: str) -> str:
    # code fences hold command examples and literal '#' lines, not
    # references/headings
    return re.sub(r"```.*?```", "", text, flags=re.DOTALL)


def _slugify(heading: str) -> str:
    """GitHub-style heading slug: drop markup, lowercase, strip anything
    but word chars/spaces/hyphens, spaces -> hyphens."""
    text = heading.strip().lower()
    text = text.replace("`", "")
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # [text](url) -> text
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def heading_anchors(md: Path) -> set[str]:
    """All anchor fragments the file's headings define (duplicate headings
    get GitHub's ``-1``, ``-2`` suffixes)."""
    seen: dict[str, int] = {}
    out: set[str] = set()
    for m in _HEADING_RE.finditer(_strip_fences(md.read_text())):
        slug = _slugify(m.group(1))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        out.add(slug if n == 0 else f"{slug}-{n}")
    return out


def check_links(md: Path, _anchor_cache: dict | None = None) -> list[str]:
    problems = []
    anchors = _anchor_cache if _anchor_cache is not None else {}
    text = _strip_fences(md.read_text())
    for target in _LINK_RE.findall(text):
        if target.startswith(_EXTERNAL):
            continue
        path, _, fragment = target.partition("#")
        resolved = (md.parent / path).resolve() if path else md
        if not resolved.exists():
            problems.append(f"{_label(md)}: broken link -> {target}")
            continue
        if fragment and resolved.suffix == ".md":
            if resolved not in anchors:
                anchors[resolved] = heading_anchors(resolved)
            if fragment not in anchors[resolved]:
                problems.append(
                    f"{_label(md)}: broken anchor -> {target} "
                    f"(no heading slugs to '#{fragment}' in "
                    f"{_label(resolved)})")
    return problems


def check_fences(md: Path, write_dir: Path | None = None) -> list[str]:
    problems = []
    for i, src in enumerate(_FENCE_RE.findall(md.read_text())):
        name = f"{_label(md)}:fence{i}"
        if write_dir is not None:
            out = write_dir / f"{md.stem}_fence{i}.py"
            out.write_text(src)
        try:
            compile(src, name, "exec")
        except SyntaxError as e:
            problems.append(f"{name}: does not compile: {e}")
    return problems


def check_cli_sync() -> list[str]:
    """``docs/cli.md`` must match the live parser (tools/gen_cli_docs.py)."""
    tools_entry = str(REPO / "tools")
    sys.path.insert(0, tools_entry)
    try:
        import gen_cli_docs
        want = gen_cli_docs.render()
    except ImportError as e:
        # rendering imports repro.session, which needs jax — report a
        # structured failure instead of a traceback so the link/anchor
        # results above still land
        return [f"docs/cli.md sync check could not import the CLI ({e}); "
                f"install runtime deps or pass --skip-cli-sync"]
    finally:
        # remove the exact entry we added — render() may itself have
        # inserted REPO/src at index 0, which a blind pop(0) would evict
        sys.path.remove(tools_entry)
    have = gen_cli_docs.OUT.read_text() if gen_cli_docs.OUT.exists() else ""
    if want != have:
        return ["docs/cli.md is out of sync with the repro.session parser "
                "— regenerate with: PYTHONPATH=src python "
                "tools/gen_cli_docs.py"]
    return []


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--write-extracted", metavar="DIR", default=None,
                    help="also write extracted fences as .py files here "
                         "(for python -m compileall)")
    ap.add_argument("--skip-cli-sync", action="store_true",
                    help="skip the docs/cli.md generated-reference check "
                         "(it imports repro.session, which needs jax)")
    args = ap.parse_args(argv)
    write_dir = None
    if args.write_extracted:
        write_dir = Path(args.write_extracted)
        write_dir.mkdir(parents=True, exist_ok=True)

    problems = []
    anchor_cache: dict = {}
    n_links = n_fences = 0
    for md in _md_files():
        problems += check_links(md, anchor_cache)
        n_links += 1
        if str(md).startswith(str(DOCS_DIR)):
            problems += check_fences(md, write_dir)
            n_fences += 1
    if not args.skip_cli_sync:
        problems += check_cli_sync()

    for p in problems:
        print(f"FAIL {p}", file=sys.stderr)
    print(f"check_docs: {n_links} files link+anchor-checked, "
          f"{n_fences} docs files fence-compiled, "
          f"cli-sync {'skipped' if args.skip_cli_sync else 'checked'}, "
          f"{len(problems)} problem(s)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
