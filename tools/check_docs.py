#!/usr/bin/env python
"""Docs checker: intra-repo markdown link validation + fenced-example compilation.

Two failure modes this guards against as the APIs evolve:

1. broken intra-repo links — every relative ``[text](target)`` in the
   checked markdown files must point at an existing file (``#anchor``
   fragments are stripped; external ``http(s)://`` / ``mailto:`` links
   are skipped);
2. stale code examples — every fenced ```` ```python ```` block in
   ``docs/`` is extracted and byte-compiled (``python -m compileall``
   semantics via :func:`compile`), so syntax drift in examples fails CI.

Usage: ``python tools/check_docs.py [--write-extracted DIR]``; exits
non-zero on any problem.  Run by the ``docs`` job in
``.github/workflows/ci.yml`` and by ``tests/test_docs.py``.
"""
from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# files whose links are validated; python fences are compiled for docs/ only
LINK_CHECKED = ["README.md", "ROADMAP.md"]
DOCS_DIR = REPO / "docs"

_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)
_EXTERNAL = ("http://", "https://", "mailto:")


def _label(md: Path) -> str:
    try:
        return str(md.relative_to(REPO))
    except ValueError:
        return str(md)


def _md_files() -> list[Path]:
    files = [REPO / f for f in LINK_CHECKED if (REPO / f).exists()]
    files += sorted(DOCS_DIR.glob("**/*.md")) if DOCS_DIR.is_dir() else []
    return files


def check_links(md: Path) -> list[str]:
    problems = []
    text = md.read_text()
    # ignore links inside code fences (command examples, not references)
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    for target in _LINK_RE.findall(text):
        if target.startswith(_EXTERNAL):
            continue
        path = target.split("#", 1)[0]
        if not path:  # pure in-page anchor
            continue
        resolved = (md.parent / path).resolve()
        if not resolved.exists():
            problems.append(f"{_label(md)}: broken link -> {target}")
    return problems


def check_fences(md: Path, write_dir: Path | None = None) -> list[str]:
    problems = []
    for i, src in enumerate(_FENCE_RE.findall(md.read_text())):
        name = f"{_label(md)}:fence{i}"
        if write_dir is not None:
            out = write_dir / f"{md.stem}_fence{i}.py"
            out.write_text(src)
        try:
            compile(src, name, "exec")
        except SyntaxError as e:
            problems.append(f"{name}: does not compile: {e}")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--write-extracted", metavar="DIR", default=None,
                    help="also write extracted fences as .py files here "
                         "(for python -m compileall)")
    args = ap.parse_args(argv)
    write_dir = None
    if args.write_extracted:
        write_dir = Path(args.write_extracted)
        write_dir.mkdir(parents=True, exist_ok=True)

    problems = []
    n_links = n_fences = 0
    for md in _md_files():
        link_problems = check_links(md)
        problems += link_problems
        n_links += 1
        if str(md).startswith(str(DOCS_DIR)):
            problems += check_fences(md, write_dir)
            n_fences += 1

    for p in problems:
        print(f"FAIL {p}", file=sys.stderr)
    print(f"check_docs: {n_links} files link-checked, "
          f"{n_fences} docs files fence-compiled, {len(problems)} problem(s)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
