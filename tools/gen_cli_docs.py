#!/usr/bin/env python
"""Generate ``docs/cli.md`` from the ``python -m repro.session`` parser.

The unified Session CLI is the repo's one command-line surface (serve,
dryrun and the benchmark drivers are thin wrappers over it).  This tool
introspects :func:`repro.session.build_parser` and writes the top-level
help plus every subcommand's help into ``docs/cli.md``, so the committed
reference can never drift from the argparse truth: ``tools/check_docs.py``
re-renders it and fails when the committed file is out of sync (the CI
docs job runs that check).

Usage:

    PYTHONPATH=src python tools/gen_cli_docs.py          # rewrite docs/cli.md
    PYTHONPATH=src python tools/gen_cli_docs.py --check  # verify, exit 1 on drift

(The src path is added automatically when PYTHONPATH is unset.)
"""
from __future__ import annotations

import argparse
import os
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
OUT = REPO / "docs" / "cli.md"

HEADER = """\
# CLI reference — `python -m repro.session`

<!-- GENERATED FILE: do not edit by hand.
     Regenerate with:  PYTHONPATH=src python tools/gen_cli_docs.py
     tools/check_docs.py (and the CI docs job) fail when this file is
     out of sync with the argparse definitions in src/repro/session.py. -->

One (arch, policy, backend) spec drives every entry point
([architecture.md](architecture.md)); the subcommands below are the
public command-line surface.  `repro.launch.serve`,
`repro.launch.dryrun` and `benchmarks/table4_resnet.py` are thin
wrappers over the same `Session` facade.  Policy files come from
[numerics_policy.md](numerics_policy.md); the proxy auto-configurer
behind `auto-configure` is documented in
[sensitivity.md](sensitivity.md).
"""


def _subparsers(ap: argparse.ArgumentParser):
    for action in ap._actions:
        if isinstance(action, argparse._SubParsersAction):
            # dict name -> subparser, insertion-ordered
            return action.choices
    return {}


def render() -> str:
    """The full docs/cli.md content (deterministic: fixed help width)."""
    if str(REPO / "src") not in sys.path and "repro" not in sys.modules:
        sys.path.insert(0, str(REPO / "src"))
    from repro.session import build_parser

    # argparse wraps help text to the terminal width; pin it so the
    # generated file is identical everywhere (laptops, CI runners) —
    # and restore it, render() runs in-process under pytest/check_docs
    prev = os.environ.get("COLUMNS")
    os.environ["COLUMNS"] = "80"
    try:
        ap = build_parser()
        parts = [HEADER, "\n## repro.session\n\n```text\n",
                 ap.format_help().rstrip(), "\n```\n"]
        for name, sub in _subparsers(ap).items():
            parts += [f"\n## repro.session {name}\n\n```text\n",
                      sub.format_help().rstrip(), "\n```\n"]
    finally:
        if prev is None:
            os.environ.pop("COLUMNS", None)
        else:
            os.environ["COLUMNS"] = prev
    return "".join(parts)


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--check", action="store_true",
                   help="verify docs/cli.md is in sync instead of writing it")
    args = p.parse_args(argv)
    text = render()
    if args.check:
        committed = OUT.read_text() if OUT.exists() else ""
        if committed != text:
            print("FAIL docs/cli.md is out of sync with repro.session's "
                  "parser — regenerate with: PYTHONPATH=src python "
                  "tools/gen_cli_docs.py", file=sys.stderr)
            return 1
        print("gen_cli_docs: docs/cli.md is in sync")
        return 0
    OUT.write_text(text)
    print(f"gen_cli_docs: wrote {OUT}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
